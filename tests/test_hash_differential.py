"""Adversarial hash-path differentials: every engine (eager / pallas / naive)
against a NumPy dict oracle on the streams that stress open addressing —
Zipfian skew, all-pairs-collide-to-one-slot, duplicate-heavy batches, and
table-near-capacity overflow — plus the wire-narrowing and stable-bucketing
satellites and the fused program-mode wordcount acceptance counters."""
import collections

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BlazeSession, distribute, make_dist_hashmap
from repro.core import containers as C
from repro.core.mapreduce import bucket_by_dest

ENGINES = ("eager", "pallas", "naive")

SESS = BlazeSession()


def _mapper(i, row, emit):
    emit(row[0].astype(jnp.int32), row[1], mask=row[2] > 0)


def _dict_oracle(keys, vals, mask, reducer="sum"):
    fn = {
        "sum": np.add, "prod": np.multiply,
        "min": np.minimum, "max": np.maximum,
    }[reducer]
    want: dict = {}
    for k, v, m in zip(keys.astype(np.int64), vals.astype(np.float64), mask):
        if m > 0:
            want[int(k)] = fn(want[int(k)], v) if int(k) in want else v
    return want


def _run(engine, keys, vals, mask, capacity, reducer="sum", **kw):
    rows = distribute(
        np.stack([keys, vals, mask], axis=1).astype(np.float32)
    )
    hm = make_dist_hashmap(SESS.mesh, capacity, (), jnp.float32, reducer)
    return SESS.map_reduce(
        rows, _mapper, reducer, hm, engine=engine, return_stats=True, **kw
    )


def _hash32_np(x: np.ndarray) -> np.ndarray:
    """Host-side splitmix32 (mirrors containers.hash32) for crafting
    collision sets."""
    with np.errstate(over="ignore"):
        x = x.astype(np.uint32)
        x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
        x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
        return x ^ (x >> np.uint32(16))


def test_host_hash_mirror_is_faithful():
    xs = np.arange(-512, 512, dtype=np.int32)
    np.testing.assert_array_equal(
        _hash32_np(xs), np.asarray(C.hash32(jnp.asarray(xs)))
    )


@pytest.mark.parametrize("reducer", ("sum", "min", "prod"))
@pytest.mark.parametrize("engine", ENGINES)
def test_zipfian_keys_match_oracle(engine, reducer):
    """Heavy skew: a handful of keys hold most of the mass — the regime the
    eager/kernel local combine exists for."""
    rng = np.random.RandomState(5)
    n = 256
    keys = rng.zipf(1.3, n).clip(max=997).astype(np.float32)
    if reducer == "prod":
        vals = rng.choice([1.0, -1.0], n).astype(np.float32)
    else:
        vals = rng.randint(-8, 9, n).astype(np.float32)
    mask = (rng.rand(n) > 0.15).astype(np.float32)
    hm, st = _run(engine, keys, vals, mask, 4096, reducer)
    st = st.finalize()
    assert st.engine == engine and hm.total_overflow() == 0
    got = {int(k): float(v) for k, v in hm.to_dict().items()}
    want = _dict_oracle(keys, vals, mask, reducer)
    assert set(got) == set(want)
    for k in want:
        assert abs(got[k] - want[k]) < 1e-4, (engine, reducer, k)
    if engine == "pallas":
        assert st.kernel_table_cap is not None
        assert st.kernel_probe_depth >= 16
        assert 0.0 < st.kernel_occupancy <= 1.0


@pytest.mark.parametrize("engine", ENGINES)
def test_all_pairs_collide_to_one_slot(engine):
    """Keys crafted so EVERY pair hashes to the same home slot of the target
    table — worst-case linear-probe clustering.  With probe room available,
    every key must still land, exactly once, with exact sums."""
    cap = 64
    pool = np.arange(1, 200_000, dtype=np.int32)
    same_slot = pool[(_hash32_np(pool) % np.uint32(cap)) == 7][:20]
    assert len(same_slot) == 20
    keys = np.repeat(same_slot, 3).astype(np.float32)  # duplicates too
    vals = np.ones(len(keys), np.float32)
    mask = np.ones(len(keys), np.float32)
    hm, st = _run(engine, keys, vals, mask, cap)
    assert hm.total_overflow() == 0
    got = {int(k): float(v) for k, v in hm.to_dict().items()}
    assert got == {int(k): 3.0 for k in same_slot}


@pytest.mark.parametrize("engine", ENGINES)
def test_duplicate_heavy_batch_matches_oracle(engine):
    """64x duplication per key: the local combine must collapse the stream
    (eager/pallas ship <= distinct * shards pairs; naive ships all)."""
    rng = np.random.RandomState(9)
    n, n_keys = 512, 8
    keys = rng.randint(0, n_keys, n).astype(np.float32)
    vals = rng.randint(1, 5, n).astype(np.float32)
    mask = np.ones(n, np.float32)
    hm, st = _run(engine, keys, vals, mask, 128)
    st = st.finalize()
    got = {int(k): float(v) for k, v in hm.to_dict().items()}
    assert got == pytest.approx(_dict_oracle(keys, vals, mask))
    n_shards = SESS.mesh.shape["data"]
    if engine == "naive":
        assert st.pairs_shipped == n
    else:
        assert st.pairs_shipped <= n_keys * n_shards


@pytest.mark.parametrize("engine", ENGINES)
def test_near_capacity_overflow_invariants(engine):
    """More distinct keys than the table can hold: drops must be *counted*
    (live + overflow covers every distinct key), survivors must hold their
    exact oracle totals, and the table never exceeds capacity."""
    n = 96
    keys = np.arange(n, dtype=np.float32)
    vals = np.full(n, 2.0, np.float32)
    mask = np.ones(n, np.float32)
    cap = 16
    hm, st = _run(engine, keys, vals, mask, cap)
    st = st.finalize()
    n_shards = hm.n_shards
    assert hm.size() <= cap * n_shards
    assert hm.total_overflow() > 0
    assert hm.size() + hm.total_overflow() == n  # conservation, exact
    for k, v in hm.to_dict().items():
        assert float(v) == pytest.approx(2.0)  # survivors exact


# -- satellite: narrowed keys on the shuffle wire ------------------------------


def test_key_range_narrows_wire_and_stays_exact():
    rng = np.random.RandomState(3)
    keys = rng.randint(0, 100, 128).astype(np.float32)
    vals = rng.randint(-4, 5, 128).astype(np.float32)
    mask = np.ones(128, np.float32)
    results = {}
    for key_range in (None, 100):
        hm, st = _run("eager", keys, vals, mask, 512, key_range=key_range)
        st = st.finalize()
        results[key_range] = st
        got = {int(k): float(v) for k, v in hm.to_dict().items()}
        assert got == pytest.approx(_dict_oracle(keys, vals, mask))
    wide, narrow = results[None], results[100]
    assert wide.pairs_shipped == narrow.pairs_shipped
    # int32+f32 = 8 B/pair -> int8 key + f32 val = 5 B/pair
    assert wide.shuffle_payload_bytes == wide.pairs_shipped * 8
    assert narrow.shuffle_payload_bytes == narrow.pairs_shipped * 5
    assert "5B" in narrow.collective and "8B" in wide.collective


def test_key_range_16bit_band():
    """A vocab over int8 range narrows to int16 (6 B/pair)."""
    rng = np.random.RandomState(4)
    keys = rng.randint(0, 1000, 64).astype(np.float32)
    vals = np.ones(64, np.float32)
    hm, st = _run(
        "pallas", keys, vals, np.ones(64, np.float32), 4096, key_range=1000
    )
    st = st.finalize()
    assert st.shuffle_payload_bytes == st.pairs_shipped * 6
    got = {int(k): float(v) for k, v in hm.to_dict().items()}
    assert got == pytest.approx(
        _dict_oracle(keys, vals, np.ones(64, np.float32))
    )


# -- satellite: stable bucketing ----------------------------------------------


def test_bucket_by_dest_stable_rank_with_duplicate_destinations():
    """With every pair bound for the SAME destination and a bucket smaller
    than the stream, the kept pairs must be the first-emitted ones in
    emission order — the stable-sort guarantee the rank logic assumes."""
    n, cap = 32, 8
    keys = jnp.full((n,), 5, jnp.int32)  # one key -> one destination
    vals = jnp.arange(n, dtype=jnp.float32)  # emission-order tag
    valid = jnp.ones((n,), bool)
    bkeys, bvals, dropped = bucket_by_dest(keys, vals, valid, 1, cap, 0.0)
    assert int(dropped) == n - cap
    np.testing.assert_array_equal(
        np.asarray(bvals[0]), np.arange(cap, dtype=np.float32)
    )
    # mixed destinations: each bucket keeps ITS first-emitted pairs in order
    keys2 = jnp.asarray(np.arange(n) % 7, jnp.int32)
    bkeys2, bvals2, dropped2 = bucket_by_dest(
        keys2, vals, valid, 4, 4, 0.0
    )
    dests = np.asarray(C.shard_of_key(keys2, 4))
    for dshard in range(4):
        mine = np.asarray(vals)[dests == dshard][:4]
        got = np.asarray(bvals2[dshard])[: len(mine)]
        np.testing.assert_array_equal(got, mine)


# -- program-mode wordcount acceptance ----------------------------------------


@pytest.mark.parametrize("engine", ("eager", "pallas"))
def test_program_mode_wordcount_fusion_counters(engine):
    """10-iteration program-mode wordcount = 1 program compile,
    ceil(10/5) = 2 dispatches, ZERO per-iteration host syncs — and the
    counts are exactly 10x the single-pass oracle."""
    from repro.core.algorithms import wordcount

    rng = np.random.RandomState(0)
    lines = rng.randint(0, 50, (32, 8)).astype(np.int32)
    lines[rng.rand(32, 8) < 0.1] = -1
    ref = collections.Counter(lines[lines >= 0].reshape(-1).tolist())

    sess = BlazeSession()
    res = wordcount(
        lines, engine=engine, mode="program", iters=10, unroll=5,
        session=sess,
    )
    assert res.program_compiles == 1
    assert res.dispatches == 2
    assert res.host_syncs == 0
    assert res.iterations == 10
    got = {int(k): int(v) for k, v in res.counts.to_dict().items()}
    assert got == {k: 10 * v for k, v in ref.items()}
    assert res.counts.total_overflow() == 0


def test_program_vs_per_op_wordcount_dispatch_gap():
    from repro.core.algorithms import wordcount

    lines = np.random.RandomState(1).randint(0, 30, (16, 8)).astype(np.int32)
    per_op = wordcount(
        lines, mode="per_op", iters=10, session=BlazeSession()
    )
    prog = wordcount(
        lines, mode="program", iters=10, unroll=5, session=BlazeSession()
    )
    assert per_op.dispatches == 10 and prog.dispatches == 2
    assert (
        {int(k): int(v) for k, v in per_op.counts.to_dict().items()}
        == {int(k): int(v) for k, v in prog.counts.to_dict().items()}
    )


def test_program_multipass_hash_then_dense():
    """A second fused pass reads the UPDATED hash table as a source —
    multi-pass aggregation without leaving the executable."""
    rng = np.random.RandomState(2)
    lines = rng.randint(0, 30, (16, 8)).astype(np.int32)
    ref = collections.Counter(lines.reshape(-1).tolist())

    from repro.core.algorithms.wordcount import wordcount_mapper

    sess = BlazeSession()
    lines_v = distribute(lines, sess.mesh)
    hm = make_dist_hashmap(sess.mesh, 256, (), jnp.int32, "sum")

    def hist_mapper(k, v, emit):
        emit(jnp.minimum(v, 15), 1)

    def step(ctx, s):
        counts = ctx.map_reduce(
            lines_v, wordcount_mapper, "sum", hm, engine="pallas",
            key_range=30,
        )
        hist = ctx.map_reduce(
            counts, hist_mapper, "sum", jnp.zeros((16,), jnp.int32)
        )
        return {"hist": hist}

    prog = sess.program(step)
    state = prog({"hist": jnp.zeros((16,), jnp.int32)}, 1)
    got = {int(k): int(v) for k, v in prog.hash_result(hm).to_dict().items()}
    assert got == dict(ref)
    hist_ref = collections.Counter(min(c, 15) for c in ref.values())
    got_hist = {
        i: int(v) for i, v in enumerate(np.asarray(state["hist"])) if v
    }
    assert got_hist == dict(hist_ref)
    assert prog.hash_slots == 1 and prog.stats.compiles == 1
