"""Measured-autotuning system tests.

Pins the tune-cache contract: one plan measures exactly once per session and
every later appearance — per-op resubmission, ``run_loop`` programs, served
queries — reuses the winner; a changed ``key_range`` or dtype is a different
plan and re-measures; tuned results stay bit-identical to untuned results
across EVERY candidate config; winners persist to disk and reload.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost
from repro.core import containers as C
from repro.core import plan as plan_mod
from repro.core.algorithms.kmeans import _program_step as _kmeans_step
from repro.core.algorithms.wordcount import _program_step as _wc_step
from repro.core.session import BlazeSession
from repro.serve.server import BlazeServer

VOCAB = 40
N_TOKENS = 192


def _tokens(seed=0, n=N_TOKENS, dtype=np.int32):
    return np.random.RandomState(seed).randint(0, VOCAB, size=(n,)).astype(
        dtype
    )


def _wc_mapper(i, tok, emit):
    emit(tok, 1, mask=tok >= 0)


def _hm(sess, dtype=jnp.int32):
    return C.make_dist_hashmap(sess.mesh, 4 * VOCAB, (), dtype, "sum")


def _counts(hm):
    keys, vals = hm.items()
    order = np.argsort(keys, kind="stable")
    return keys[order], vals[order]


def _wc(sess, *, tune=False, key_range=VOCAB, dtype=np.int32):
    lines = C.distribute(_tokens(dtype=dtype), sess.mesh)
    out = sess.map_reduce(
        lines, _wc_mapper, "sum",
        _hm(sess, jnp.dtype(dtype)), key_range=key_range, tune=tune,
    )
    return _counts(out)


# -- measure-once semantics ---------------------------------------------------


def test_map_reduce_measures_once_and_reuses():
    sess = BlazeSession()
    _wc(sess, tune=True)
    first = sess.stats.tune_measurements
    assert first > 0
    assert len(sess.tuning) == 1
    (tk, cfg), = sess.tuning.items()
    assert cfg.source == "measured" and cfg.wall_s is not None
    # resubmission of the same plan: zero new measurements
    _wc(sess, tune=True)
    _wc(sess, tune=False)
    assert sess.stats.tune_measurements == first
    assert len(sess.tuning) == 1


def test_different_key_range_or_dtype_remeasures():
    sess = BlazeSession()
    _wc(sess, tune=True, key_range=VOCAB)
    assert len(sess.tuning) == 1
    _wc(sess, tune=True, key_range=2 * VOCAB)  # different plan hash
    assert len(sess.tuning) == 2
    _wc(sess, tune=True, dtype=np.float32)  # different value dtype
    assert len(sess.tuning) == 3


def test_program_tune_measures_once_across_run_loop_blocks():
    sess = BlazeSession()
    pts = np.random.RandomState(0).randint(-3, 4, size=(256, 4)).astype(
        np.float32
    )
    pts_v = C.distribute(pts, sess.mesh)
    step, state0 = _kmeans_step(pts_v, 8, 4, "auto", "none")
    prog = sess.program(step, mesh=sess.mesh, tune=True)
    c0 = jnp.asarray(pts[:8])
    sess.run_loop(prog, state0(c0), max_iters=6, unroll=2)
    first = sess.stats.tune_measurements
    assert first > 0
    # more blocks, a second tuned program, and an untuned one: no re-measure
    sess.run_loop(prog, state0(c0), max_iters=4)
    prog2 = sess.program(step, mesh=sess.mesh, tune=True)
    sess.run_loop(prog2, state0(c0), max_iters=2)
    assert sess.stats.tune_measurements == first


def test_tuned_node_annotated_in_plan():
    sess = BlazeSession()
    pts = np.random.RandomState(1).randn(128, 4).astype(np.float32)
    pts_v = C.distribute(pts, sess.mesh)
    step, state0 = _kmeans_step(pts_v, 4, 4, "auto", "none")
    prog = sess.program(step, mesh=sess.mesh, tune=True)
    prog.build(state0(jnp.asarray(pts[:4])))
    nodes = [
        n for n in prog.plan.mapreduce_nodes()
        if not n.dead and n.cse_of is None
    ]
    assert any(n.tuned is not None for n in nodes)
    tuned = next(n for n in nodes if n.tuned is not None)
    assert tuned.tuned.source == "measured"
    assert tuned.engine == tuned.tuned.engine
    rendered = prog.plan.render()
    assert "tuned measured:" in rendered and "cost~" in rendered


# -- bit-equality across every candidate config -------------------------------


def test_dense_candidates_bit_identical():
    pts = np.random.RandomState(2).randint(-4, 5, size=(256, 4)).astype(
        np.float32
    )
    k = 8
    ref = None
    sess = BlazeSession()
    pts_v = C.distribute(pts, sess.mesh)
    step, state0 = _kmeans_step(pts_v, k, 4, "auto", "none")
    state = state0(jnp.asarray(pts[:k]))
    cands = cost.dense_tuning_candidates(k, 6, "sum", jnp.float32)
    assert len(cands) >= 2
    for cfg in cands:
        prog = sess.program(step, mesh=sess.mesh)
        probe = prog.build(state)
        node = next(
            n for n in probe.mapreduce_nodes()
            if not n.dead and n.cse_of is None
        )
        tuned_sess = BlazeSession()
        tuned_sess.tuning.put(node.tune_key, cfg)
        tv = C.distribute(pts, tuned_sess.mesh)
        step_t, state0_t = _kmeans_step(tv, k, 4, "auto", "none")
        prog_t = tuned_sess.program(step_t, mesh=tuned_sess.mesh)
        out, _ = tuned_sess.run_loop(
            prog_t, state0_t(jnp.asarray(pts[:k])), max_iters=5
        )
        got = np.asarray(out["centers"])
        if ref is None:
            ref = got
        else:
            assert np.array_equal(ref, got), cfg


def test_hash_candidates_bit_identical():
    ref = None
    cands = cost.hash_tuning_candidates(
        1, "sum", jnp.int32, key_range=VOCAB
    )
    assert len(cands) >= 2
    # derive the node's tune_key once from an untuned session
    probe_sess = BlazeSession()
    lines = C.distribute(_tokens(), probe_sess.mesh)
    node = plan_mod.build_mapreduce_node(
        idx=0, kind="vector", src="s", source_key=None, mapper=_wc_mapper,
        red=__import__("repro.core.reducers", fromlist=["get_reducer"])
        .get_reducer("sum"),
        target=_hm(probe_sess), engine="auto", wire="none",
        key_range=VOCAB, env=None,
    )
    for cfg in cands:
        sess = BlazeSession()
        sess.tuning.put(node.tune_key, cfg)
        got = _wc(sess, tune=False)
        if ref is None:
            ref = got
        else:
            assert np.array_equal(ref[0], got[0]), cfg
            assert np.array_equal(ref[1], got[1]), cfg


# -- persistence --------------------------------------------------------------


def test_save_load_skips_measurement(tmp_path):
    p = str(tmp_path / "tuning.json")
    sess = BlazeSession(tuning_path=p)
    _wc(sess, tune=True)
    assert sess.stats.tune_measurements > 0
    sess.save_tuning()
    s2 = BlazeSession(tuning_path=p)
    assert len(s2.tuning) == len(sess.tuning)
    _wc(s2, tune=True)
    assert s2.stats.tune_measurements == 0  # winner came off disk
    with pytest.raises(ValueError):
        BlazeSession().save_tuning()  # no path configured anywhere


# -- serving ------------------------------------------------------------------


def test_serve_tuning_stats_conservation():
    rng = np.random.RandomState(0)
    pts = rng.randn(128, 4).astype(np.float32)
    lines = rng.randint(0, VOCAB, size=(128, 1)).astype(np.int32)
    srv = BlazeServer(tune=True)
    srv.register_dataset("points", pts)
    srv.register_dataset("lines", lines, vocab_size=VOCAB)
    srv.start()
    try:
        srv.submit_and_wait(
            "t", "kmeans", {"k": 4, "iters": 2, "engine": "auto"}
        )
        srv.submit_and_wait("t", "wordcount", {"engine": "auto"})
        measured = srv.session.stats.tune_measurements
        assert measured > 0
        # resubmission: plan-cache hit, no re-measure
        srv.submit_and_wait(
            "t", "kmeans", {"k": 4, "iters": 2, "engine": "auto"}
        )
        assert srv.session.stats.tune_measurements == measured
        snap = srv.stats_snapshot()
        t = snap["tuning"]
        assert (
            t["tuned_plans"] + t["fallback_plans"]
            == snap["resident_programs"]
        )
        assert t["tuned_plans"] >= 1
        for info in t["plans"].values():
            for op in info["ops"]:
                assert op["source"] in ("measured", "loaded", "model",
                                        "fallback")
                if op["source"] == "model":
                    assert op["config"] is None
                else:
                    assert op["config"]
        assert t["cache"]["measurements"] == measured
    finally:
        srv.stop()


def test_serve_untuned_plans_are_fallback():
    rng = np.random.RandomState(0)
    srv = BlazeServer()  # tune off: everything rides the model
    srv.register_dataset("points", rng.randn(64, 4).astype(np.float32))
    srv.start()
    try:
        srv.submit_and_wait(
            "t", "kmeans", {"k": 4, "iters": 2, "engine": "auto"}
        )
        snap = srv.stats_snapshot()
        t = snap["tuning"]
        assert t["tuned_plans"] == 0
        assert t["fallback_plans"] == snap["resident_programs"] == 1
        assert srv.session.stats.tune_measurements == 0
    finally:
        srv.stop()
