"""Multi-device correctness: these tests spawn a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test process
must keep seeing 1 device, per the harness contract) and assert that the
engine produces identical results on a real 8-shard mesh."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_mapreduce_8dev_matches_oracle():
    res = _run(
        """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core import data_mesh, distribute, make_dist_hashmap, map_reduce
import collections
assert len(jax.devices()) == 8
mesh = data_mesh()
words = np.random.RandomState(0).randint(0, 100, 5000).astype(np.int32)
wv = distribute(words, mesh)
def m(i, w, emit): emit(w, 1)
out = {}
for engine in ("eager", "naive"):
    hm = make_dist_hashmap(mesh, 1024, (), jnp.int32, "sum")
    hm2, st = map_reduce(wv, m, "sum", hm, mesh=mesh, engine=engine, return_stats=True)
    d = hm2.to_dict()
    ref = collections.Counter(words.tolist())
    out[engine] = {
        "correct": all(int(d.get(k, 0)) == c for k, c in ref.items()) and len(d) == len(ref),
        "overflow": hm2.total_overflow(),
        "shipped": int(st.finalize().pairs_shipped),
        "emitted": int(st.finalize().pairs_emitted),
    }
print(json.dumps(out))
"""
    )
    assert res["eager"]["correct"] and res["naive"]["correct"]
    assert res["eager"]["overflow"] == 0
    # eager reduction ships (far) fewer pairs than it emits on 8 shards
    assert res["eager"]["shipped"] < res["eager"]["emitted"]
    assert res["eager"]["shipped"] <= res["naive"]["shipped"]


def test_hash_kernel_8dev_matches_oracle():
    """engine="pallas" hash path on a real 8-shard mesh: kernel combine on
    every shard, narrowed-key all_to_all, kernel merge — dict-oracle exact,
    and the fused program-mode wordcount keeps its counters."""
    res = _run(
        """
import json, collections, numpy as np, jax, jax.numpy as jnp
from repro.core import BlazeSession, distribute, make_dist_hashmap
from repro.core.algorithms import wordcount
assert len(jax.devices()) == 8
sess = BlazeSession()
words = np.random.RandomState(0).randint(0, 100, 4000).astype(np.int32)
wv = distribute(words, sess.mesh)
def m(i, w, emit): emit(w, 1)
ref = collections.Counter(words.tolist())
hm = make_dist_hashmap(sess.mesh, 256, (), jnp.int32, "sum")
hm, st = sess.map_reduce(wv, m, "sum", hm, engine="pallas", key_range=100,
                         return_stats=True)
st = st.finalize()
d = hm.to_dict()
lines = words.reshape(-1, 16)
prog_res = wordcount(lines, engine="pallas", mode="program", iters=10,
                     unroll=5, session=BlazeSession())
pd = prog_res.counts.to_dict()
print(json.dumps({
    "correct": all(int(d.get(k, 0)) == c for k, c in ref.items())
               and len(d) == len(ref),
    "engine": st.engine,
    "overflow": hm.total_overflow(),
    "payload": int(st.shuffle_payload_bytes),
    "shipped": int(st.pairs_shipped),
    "prog_correct": all(int(pd.get(k, 0)) == 10 * c for k, c in ref.items()),
    "prog_compiles": prog_res.program_compiles,
    "prog_dispatches": prog_res.dispatches,
    "prog_syncs": prog_res.host_syncs,
}))
"""
    )
    assert res["correct"] and res["engine"] == "pallas"
    assert res["overflow"] == 0
    # narrowed keys: int8 key + int32 val = 5 B per shipped pair
    assert res["payload"] == res["shipped"] * 5
    assert res["prog_correct"]
    assert res["prog_compiles"] == 1
    assert res["prog_dispatches"] == 2 and res["prog_syncs"] == 0


def test_pagerank_8dev_matches_reference():
    res = _run(
        """
import json, numpy as np, jax
from repro.core import data_mesh
from repro.core.algorithms import pagerank, pagerank_reference
from repro.data.synthetic import rmat_edges
mesh = data_mesh()
edges = rmat_edges(7, 8, seed=2)
res = pagerank(edges, 128, tol=1e-7, max_iters=80, mesh=mesh)
ref = pagerank_reference(edges, 128, tol=1e-7, max_iters=80)
err = float(np.abs(res.scores - ref).max() / ref.max())
print(json.dumps({"err": err, "iters": res.iterations}))
"""
    )
    assert res["err"] < 1e-4


def test_fused_program_8dev_matches_reference():
    """The fused-iteration path on a real 8-shard mesh: collectives inside the
    device-resident fori_loop, one program compile, ⌈N/unroll⌉ dispatches."""
    res = _run(
        """
import json, numpy as np, jax
from repro.core import BlazeSession, data_mesh
from repro.core.algorithms import kmeans, kmeans_reference, pagerank, pagerank_reference
from repro.data.synthetic import cluster_points, rmat_edges
assert len(jax.devices()) == 8
mesh = data_mesh()
sess = BlazeSession(mesh)
edges = rmat_edges(7, 8, seed=2)
pr = pagerank(edges, 128, tol=0.0, max_iters=10, mesh=mesh, session=sess,
              mode="program", unroll=5)
pr_ref = pagerank_reference(edges, 128, tol=0.0, max_iters=10)
# int8 wire: per-shard feedback residuals sharded over the 8-way mesh
pr8 = pagerank(edges, 128, tol=0.0, max_iters=10, mesh=mesh, session=sess,
               mode="program", unroll=2, wire="int8")
pts, _ = cluster_points(2000, 3, 4, seed=0)
init = pts[:4].copy()
km = kmeans(pts, 4, init_centers=init, tol=0.0, max_iters=10, mesh=mesh,
            session=sess, mode="program", unroll=5)
km_ref, _ = kmeans_reference(pts, init, tol=0.0, max_iters=10)
print(json.dumps({
    "pr_err": float(np.abs(pr.scores - pr_ref).max() / pr_ref.max()),
    "pr_compiles": pr.program_compiles, "pr_dispatches": pr.dispatches,
    "pr_int8_err": float(np.abs(pr8.scores - pr_ref).max() / pr_ref.max()),
    "km_err": float(np.abs(km.centers - km_ref).max()),
    "km_compiles": km.program_compiles, "km_dispatches": km.dispatches,
}))
"""
    )
    assert res["pr_err"] < 1e-4
    assert res["pr_compiles"] == 1 and res["pr_dispatches"] == 2
    assert res["pr_int8_err"] < 2e-2
    assert res["km_err"] < 1e-2
    # 2 fused-loop dispatches + the final inertia probe (same executable)
    assert res["km_compiles"] == 1 and res["km_dispatches"] == 3


def test_compressed_psum_8dev():
    res = _run(
        """
import json, numpy as np, jax, jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core.containers import data_mesh
from repro.distributed.collectives import compressed_psum
mesh = data_mesh()
x = jnp.asarray(np.random.RandomState(0).randn(8, 128).astype(np.float32))
out = {}
for wire in ("none", "bf16", "int8"):
    f = shard_map(lambda v: compressed_psum(v[0], "data", wire=wire)[None],
                  mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False)
    got = jax.jit(f)(x)
    exact = np.asarray(x).sum(0)
    out[wire] = float(np.abs(np.asarray(got)[0] - exact).max() / np.abs(exact).max())
print(json.dumps(out))
"""
    )
    assert res["none"] < 1e-6
    assert res["bf16"] < 0.05
    assert res["int8"] < 0.05


def test_sharded_train_step_8dev():
    """A reduced model trains under a (2 data, 4 model) mesh with the
    production sharding policy — loss finite and decreasing."""
    res = _run(
        """
import json, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.compat import AxisType, make_mesh, set_mesh
from repro.configs.base import get_arch
from repro.distributed import sharding as SH
from repro.models import model as M
from repro.optim.adamw import AdamW
import dataclasses
cfg = dataclasses.replace(get_arch("qwen3-0.6b").reduced(), d_model=64, d_ff=128)
mesh = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
mi = SH.make_mesh_info(mesh)
params = M.init(jax.random.PRNGKey(0), cfg)
pspecs = SH.param_pspecs(cfg, params, mi)
params = jax.device_put(params, SH.named(pspecs, mi))
opt = AdamW(lr=1e-3)
ostate = opt.init(params)
def step(p, o, x, y):
    loss, g = jax.value_and_grad(lambda q: M.loss_fn(q, cfg, x, y, remat=True))(p)
    p, o = opt.update(g, o, p)
    return p, o, loss
with set_mesh(mesh):
    jstep = jax.jit(step)
    rng = np.random.RandomState(0)
    losses = []
    for i in range(8):
        x = jnp.asarray(rng.randint(0, cfg.vocab, (4, 16)), jnp.int32)
        params, ostate, loss = jstep(params, ostate, x, x)
        losses.append(float(loss))
print(json.dumps({"first": losses[0], "last": losses[-1]}))
"""
    )
    assert res["last"] < res["first"]


def test_node_data_mesh_differential_matrix_8dev():
    """The ("node","data") topology matrix: dense AND hash engines on every
    8-device node split (2x4, 4x2), hierarchical and flat, against NumPy /
    dict oracles.  Dense sums use integer-valued floats so hierarchical
    reassociation is exact — hier must be bit-equal to flat; hash targets
    (point-to-point shuffle, never hierarchical) must stay dict-oracle
    exact on the 2-D mesh."""
    res = _run(
        """
import json, collections, numpy as np, jax, jax.numpy as jnp
from repro.core import make_dist_hashmap
from repro.core.session import BlazeSession
from repro.launch.mesh import make_node_data_mesh

rng = np.random.RandomState(0)
vals = rng.randint(0, 100, (128, 4)).astype(np.float32)
words = rng.randint(0, 100, 4000).astype(np.int32)
ref_counts = collections.Counter(words.tolist())

def dense_m(i, row, emit):
    emit(0, row)

def tok_m(i, w, emit):
    emit(w, 1)

out = {}
for n_nodes in (2, 4):
    mesh = make_node_data_mesh(n_nodes)
    s = BlazeSession(mesh=mesh)
    v = s.distribute(vals)
    wv = s.distribute(words)
    r = {}
    for engine in ("eager", "naive"):
        t = jnp.zeros((1, 4), jnp.float32)
        hier = s.map_reduce(v, dense_m, "sum", t, engine=engine)
        flat = s.map_reduce(v, dense_m, "sum", t, engine=engine,
                            hierarchical=False)
        r["dense_" + engine] = {
            "oracle": bool(np.array_equal(np.asarray(hier)[0], vals.sum(0))),
            "bit_equal": np.asarray(hier).tobytes()
                         == np.asarray(flat).tobytes(),
        }
    for engine in ("eager", "pallas"):
        hm = make_dist_hashmap(mesh, 1024, (), jnp.int32, "sum")
        hm, st = s.map_reduce(wv, tok_m, "sum", hm, engine=engine,
                              key_range=100, return_stats=True)
        st = st.finalize()
        d = hm.to_dict()
        r["hash_" + engine] = {
            "oracle": all(int(d.get(k, 0)) == c for k, c in ref_counts.items())
                      and len(d) == len(ref_counts),
            "overflow": hm.total_overflow(),
            "engine": st.engine,
            "intra": int(st.intra_bytes),
            "inter": int(st.inter_bytes),
        }
    out[str(n_nodes)] = r
print(json.dumps(out))
"""
    )
    for n_nodes in (2, 4):
        r = res[str(n_nodes)]
        for k in ("dense_eager", "dense_naive"):
            assert r[k]["oracle"], (n_nodes, k)
            assert r[k]["bit_equal"], (n_nodes, k)
        for k in ("hash_eager", "hash_pallas"):
            assert r[k]["oracle"], (n_nodes, k)
            assert r[k]["overflow"] == 0
        assert r["hash_pallas"]["engine"] == "pallas"
        # the all_to_all shuffle sends (n_shards - n_shards/nodes)/n_shards
        # of the payload across nodes: 4/8 at 2 nodes, 6/8 at 4.
        tot = r["hash_eager"]["intra"] + r["hash_eager"]["inter"]
        frac = (8 - 8 // n_nodes) / 8
        assert tot > 0
        assert abs(r["hash_eager"]["inter"] - tot * frac) <= 1
